"""Wire-efficient push/pull (PR 8): compressed pushes through the tick
engines and versioned parameter-diff pulls.

Four sections, all on REAL engines (eager, CPU):

* ``push``: transfer bytes of an identical push workload under fp32,
  bf16, and int8 -- straight from the engines' ``TickStats`` byte
  counters (``wire_bytes`` model: int8 ships 1B/elem + one fp32 scale
  per 2048-block).  The acceptance row asserts int8 <= 0.5x fp32.

* ``convergence``: the price of those bytes.  The same quadratic
  workload trains to convergence uncompressed and int8-compressed with
  error feedback; the gap between final losses must stay within the
  documented tolerance (EF-SGD keeps the compressed chain convergent --
  the gap is quantization noise, not divergence).

* ``pull``: versioned diff pulls vs dirty fraction.  K co-resident jobs
  share the engine; a reader holds a version vector per job and only a
  ``dirty_fraction`` subset of jobs steps between pull rounds.  Diff
  bytes must track the dirty fraction of full-pull bytes (untouched
  jobs cost ~0: a vector compare and an empty diff).

* ``parity``: compression-off fused fleet tick vs the sequential
  ``ShardedServiceRuntime.step`` oracle, bit-exact -- the compressed
  path must be invisible when no job opts in.

Run: PYTHONPATH=src python benchmarks/run.py --only wire \
         --json BENCH_wire.json
"""

import os

CONVERGENCE_GAP_TOL = 0.05  # |loss_int8 - loss_fp32| <= tol * (1 + loss_fp32)


def _smoke() -> bool:
    return bool(os.environ.get("HOTPATH_SMOKE"))


def _trees():
    import jax

    def tree(key, sizes):
        ks = jax.random.split(key, len(sizes))
        return {f"t{i}": jax.random.normal(k, (n,))
                for i, (k, n) in enumerate(zip(ks, sizes))}

    return {
        "a": tree(jax.random.PRNGKey(0), (96, 32, 64)),
        "b": tree(jax.random.PRNGKey(1), (64, 32)),
        "c": tree(jax.random.PRNGKey(2), (48, 16)),
    }


def _loss():
    import jax.numpy as jnp

    def loss(params, batch):
        return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
                   for k in params)

    return loss


def _build(n_shards=3, compression=None, trees=None, **engine_opts):
    """Sharded runtime + engine; ``compression`` applies to EVERY job."""
    import jax

    from repro.core import ParameterService
    from repro.ps.service_runtime import ShardedServiceRuntime

    trees = _trees() if trees is None else trees
    targets = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
               for j, t in trees.items()}
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(max_staleness=0, jit=False, **engine_opts)
    for jid, t in trees.items():
        nb = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss(), lr=0.05, required_servers=1,
                   agg_throughput=nb / 0.2,
                   **({"push_compression": compression}
                      if compression else {}))
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng, targets


def _run_steps(eng, targets, n):
    for _ in range(n):
        for j in targets:
            eng.step(j, {"target": targets[j]})
    eng.drain()


def _push_rows():
    n_steps = 8 if _smoke() else 30
    stats = {}
    for kind in (None, "bf16", "int8"):
        rt, eng, targets = _build(compression=kind)
        _run_steps(eng, targets, n_steps)
        stats[kind] = (eng.stats.push_bytes_raw, eng.stats.push_bytes_wire)
    raw = stats[None][0]
    assert raw == stats[None][1], "uncompressed wire must equal raw"
    r_bf16 = stats["bf16"][1] / raw
    r_int8 = stats["int8"][1] / raw
    return [
        ("wire/push_bytes_fp32", str(stats[None][1]),
         f"{n_steps} step rounds x 3 jobs, uncompressed (raw fp32)"),
        ("wire/push_bytes_bf16", str(stats["bf16"][1]),
         "same workload, push_compression='bf16'"),
        ("wire/push_bytes_int8", str(stats["int8"][1]),
         "same workload, push_compression='int8' (payload + block "
         "scales)"),
        ("wire/push_ratio_bf16", f"{r_bf16:.4f}", "bf16 / fp32 bytes"),
        ("wire/push_ratio_int8", f"{r_int8:.4f}", "int8 / fp32 bytes"),
        ("wire/push_int8_halved", str(int(r_int8 <= 0.5)),
         "acceptance: int8 pushes cost <= 0.5x fp32 on the wire "
         "(must be 1)"),
    ]


def _convergence_rows():
    n_steps = 15 if _smoke() else 60

    def final_losses(kind):
        rt, eng, targets = _build(compression=kind)
        last = {}
        for _ in range(n_steps):
            for j in targets:
                last[j] = eng.step(j, {"target": targets[j]})
        eng.drain()
        return {j: float(m["loss"]) for j, m in last.items()}

    base = final_losses(None)
    comp = final_losses("int8")
    worst = max(abs(comp[j] - base[j]) / (1.0 + base[j]) for j in base)
    return [
        ("wire/convergence_loss_fp32", f"{sum(base.values()):.6f}",
         f"summed final losses after {n_steps} step rounds, "
         f"uncompressed"),
        ("wire/convergence_loss_int8", f"{sum(comp.values()):.6f}",
         "same schedule with int8 + error feedback"),
        ("wire/convergence_gap_rel", f"{worst:.6f}",
         "worst per-job |int8 - fp32| / (1 + fp32) final-loss gap"),
        ("wire/convergence_gap_ok",
         str(int(worst <= CONVERGENCE_GAP_TOL)),
         f"acceptance: EF-compressed training lands within "
         f"{CONVERGENCE_GAP_TOL} relative gap of fp32 (must be 1)"),
    ]


def _pull_rows():
    import numpy as np

    rounds = 4 if _smoke() else 10
    rt, eng, targets = _build()
    jobs = list(targets)
    _run_steps(eng, targets, 2)  # all jobs materialized
    vectors = {}
    full_per_round = 0
    for j in jobs:
        d = eng.pull(j, since_version=0)  # bootstrap: full payload
        vectors[j] = d.version
        full_per_round += d.bytes_full

    dirty = jobs[:1]  # 1 of 3 jobs steps between pull rounds
    wire = full = 0
    for _ in range(rounds):
        for j in dirty:
            eng.step(j, {"target": targets[j]})
        eng.drain()
        for j in jobs:
            d = eng.pull(j, since_version=vectors[j])
            assert not d.full, "vector held across ticks must diff-pull"
            vectors[j] = d.version
            wire += d.bytes_wire
            full += d.bytes_full
    dirty_frac = sum(
        np.asarray(rt.splan.job_layout(j).packed_len) for j in dirty
    ) / sum(np.asarray(rt.splan.job_layout(j).packed_len) for j in jobs)
    ratio = wire / full
    return [
        ("wire/pull_bytes_full", str(full),
         f"{rounds} pull rounds x {len(jobs)} jobs, full-pull cost"),
        ("wire/pull_bytes_diff", str(wire),
         f"same rounds as versioned diffs ({len(dirty)}/{len(jobs)} "
         f"jobs dirty per round)"),
        ("wire/pull_dirty_fraction", f"{float(dirty_frac):.4f}",
         "dirty jobs' share of the pulled bytes"),
        ("wire/pull_ratio", f"{ratio:.4f}", "diff / full pull bytes"),
        ("wire/pull_tracks_dirty", str(int(ratio <= float(dirty_frac))),
         "acceptance: diff pulls move <= dirty-fraction x full-pull "
         "bytes (must be 1)"),
        ("wire/pull_diff_count", str(eng.stats.n_diff_pulls),
         "versioned pulls served as diffs (vs "
         f"{eng.stats.n_full_pulls} full)"),
    ]


def _parity_rows():
    import numpy as np

    n_steps = 8 if _smoke() else 25
    trees = _trees()
    rt, eng, targets = _build(trees=trees)  # compression off
    _run_steps(eng, targets, n_steps)

    from repro.core import ParameterService
    from repro.ps.service_runtime import ShardedServiceRuntime

    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    oracle = ShardedServiceRuntime(svc, jit=False)
    for jid, t in trees.items():
        nb = sum(4 * v.size for v in t.values())
        oracle.add_job(jid, t, _loss(), lr=0.05, required_servers=1,
                       agg_throughput=nb / 0.2)
    svc.scale_out(2)
    for _ in range(n_steps):
        for j in targets:
            oracle.step(j, {"target": targets[j]})

    mismatches = 0
    for j in targets:
        p, q = rt.params_of(j), oracle.params_of(j)
        for k in p:
            if not np.array_equal(np.asarray(p[k]), np.asarray(q[k])):
                mismatches += 1
    return [
        ("wire/parity_steps", str(n_steps),
         "step rounds compared, fused fleet tick vs sequential "
         "runtime.step"),
        ("wire/parity_bit_exact", str(int(mismatches == 0)),
         "acceptance: with push_compression=None the fused tick "
         "trajectory is bit-exact vs the per-job oracle (must be 1)"),
    ]


def rows():
    return (_push_rows() + _convergence_rows() + _pull_rows()
            + _parity_rows())


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
