"""Fig. 7: single-job AutoPS (balanced placement) vs ps-lite (round-robin).

Two measurements:
  * control plane: max-shard/mean-shard aggregation load (the slowest shard
    paces every Pull barrier, so the modeled speedup is rr_imbalance /
    balanced_imbalance);
  * data plane: padding waste of the PS flat layout under both placements
    (padded bytes are wasted all-gather traffic + idle optimizer lanes).
"""

import jax
import jax.numpy as jnp

from repro.configs.paper_workloads import make_job
from repro.core.assignment import (
    balanced_shard_assignment,
    round_robin_shard_assignment,
    shard_imbalance,
)
from repro.ps.runtime import build_flat_plan, plan_padding_waste


def rows():
    out = []
    for model, servers in (("alexnet", 2), ("vgg19", 2), ("awd-lm", 2), ("bert", 4)):
        job = make_job(model, "j", servers, 2, chunk_bytes=1 << 62)  # whole tensors
        rr = shard_imbalance(round_robin_shard_assignment(job, servers))
        bal = shard_imbalance(balanced_shard_assignment(job, servers))
        out.append((f"fig7/speedup_model/{model}-{servers}s", f"{rr / bal:.3f}",
                    f"rr_imb={rr:.3f} bal_imb={bal:.3f} upper bound; paper "
                    f"measures <=1.17x (aggregation partly hidden by compute)"))

    # Data plane: flat-PS plan waste for a real model (qwen1.5-0.5b params).
    from repro.configs import registry
    from repro.models import transformer as tf

    cfg = registry.get_smoke_config("qwen1.5-0.5b")
    abstract = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    for mode in ("balanced", "round_robin"):
        plan = build_flat_plan(abstract, n_shards=4, mode=mode)
        out.append((f"fig7/flatps_padding_waste/{mode}",
                    f"{plan_padding_waste(plan):.4f}",
                    "fraction of pull/push bytes wasted on shard padding"))
    return out
