"""Table 3 + App. B: tensor-migration overhead vs checkpoint-restart.

Measures, on the real data plane (qwen1.5-0.5b smoke-size PS state):
  * migration: relayout of the flat PS state between two assignment plans
    (jnp.take permutation), wall-clock on this host + the overlap model's
    worker-visible stall for the published testbed parameters;
  * strawman: full checkpoint save + restore through repro.checkpoint.
"""

import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.paper_workloads import model_bytes
from repro.core.migration import checkpoint_restart_cost, migration_cost
from repro.ps.elastic import migrate_flat_state, migration_bytes
from repro.ps.runtime import build_flat_plan, init_ps_state


def rows():
    out = []
    # Analytic overlap model with the paper's testbed numbers (100 Gbps).
    for model, window in (("alexnet", 0.065), ("vgg19", 0.55),
                          ("awd-lm", 0.15), ("bert", 0.25)):
        cost = migration_cost(model_bytes(model), link_bandwidth=12.5e9,
                              compute_window=window)
        naive = checkpoint_restart_cost(model_bytes(model), storage_bandwidth=1e9)
        out.append((f"table3/visible_stall_ms/{model}",
                    f"{cost.visible_stall * 1e3:.1f}",
                    f"paper: 13.6-43.8 ms; ckpt-restart {naive:.0f}s"))

    # Measured on the data plane: a ~32M-param state (AWD-LM scale, 384 MB
    # of master copy + moments), 4-shard plan change.
    key = jax.random.PRNGKey(0)
    params = {
        f"t{i}": jax.random.normal(k, (n,))
        for i, (k, n) in enumerate(zip(
            jax.random.split(key, 6),
            (13_000_000, 10_000_000, 7_000_000, 2_000_000, 500_000, 33_000),
        ))
    }
    plan_a = build_flat_plan(params, n_shards=4, mode="round_robin")
    plan_b = build_flat_plan(params, n_shards=4, mode="balanced")
    state = init_ps_state(plan_a, params)

    t0 = time.perf_counter()
    new_state = migrate_flat_state(state, plan_a, plan_b)
    jax.block_until_ready(new_state["flat"])
    t_mig = time.perf_counter() - t0
    moved = migration_bytes(plan_a, plan_b)
    out.append(("table3/measured_migration_s", f"{t_mig:.4f}",
                f"{moved / 1e6:.1f} MB of master+moments moved"))

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        save_checkpoint(d, 0, state)
        restored = restore_checkpoint(d, 0, jax.eval_shape(lambda: state))
        jax.block_until_ready(restored["flat"])
        t_ckpt = time.perf_counter() - t0
    out.append(("table3/measured_ckpt_restart_s", f"{t_ckpt:.4f}",
                f"migration is {t_ckpt / max(t_mig, 1e-9):.1f}x cheaper"))
    return out
