"""Table 3 + App. B: tensor-migration overhead vs checkpoint-restart.

Measures, on the real data plane:
  * migration: relayout of the shared flat PS state between two *compiled*
    ServicePlans -- the plans a live ParameterService produced before and
    after a placement change (job exit + Aggregator recycling), not a
    synthetic re-assignment.  Wall-clock on this host + the overlap model's
    worker-visible stall for the published testbed parameters;
  * strawman: full (plan, state) checkpoint save + cross-plan restore
    through repro.checkpoint.
"""

import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_ps_checkpoint, save_ps_checkpoint
from repro.configs.paper_workloads import model_bytes
from repro.core import ParameterService
from repro.core.migration import checkpoint_restart_cost, migration_cost
from repro.ps.elastic import (
    compile_migration_delta,
    migrate_flat_state,
    migrate_flat_state_delta,
    migration_bytes,
)
from repro.ps.runtime import init_shared_state, job_profile_from_tree

# Two ~8M-parameter jobs (32 MB of master copy each); aggregation profiled
# at 40 MB/s per server unit so packing decisions are non-degenerate.
_SIZES = (3_000_000, 2_500_000, 1_000_000, 800_000, 500_000, 200_000)
_AGG_THROUGHPUT = 4e7


def _tree(key, sizes=_SIZES):
    return {
        f"t{i}": jax.random.normal(k, (n,))
        for i, (k, n) in enumerate(zip(jax.random.split(key, len(sizes)), sizes))
    }


def rows():
    out = []
    # Analytic overlap model with the paper's testbed numbers (100 Gbps).
    for model, window in (("alexnet", 0.065), ("vgg19", 0.55),
                          ("awd-lm", 0.15), ("bert", 0.25)):
        cost = migration_cost(model_bytes(model), link_bandwidth=12.5e9,
                              compute_window=window)
        naive = checkpoint_restart_cost(model_bytes(model), storage_bandwidth=1e9)
        out.append((f"table3/visible_stall_ms/{model}",
                    f"{cost.visible_stall * 1e3:.1f}",
                    f"paper: 13.6-43.8 ms; ckpt-restart {naive:.0f}s"))

    # Measured on the data plane: two jobs share one service; job A's exit
    # triggers Aggregator recycling, so job B's tensors consolidate -- the
    # replan every surviving job rides through without restart.
    svc = ParameterService(total_budget=16, n_clusters=1)
    trees = {jid: _tree(jax.random.PRNGKey(i))
             for i, jid in enumerate(("a", "b"))}
    for jid, tree in trees.items():
        profile, specs = job_profile_from_tree(
            jid, tree, required_servers=2, agg_throughput=_AGG_THROUGHPUT)
        svc.register_job(profile, specs=specs)
    plan_a = svc.compile_plan()
    svc.job_exit("a")
    plan_b = svc.compile_plan()

    state = init_shared_state(plan_a)
    state["flat"] = jax.random.normal(jax.random.PRNGKey(9), (plan_a.total_len,))
    jax.block_until_ready(state["flat"])

    def _copy(s):
        # The delta path may donate its input buffers; every timed call
        # gets its own copy so `state` survives for the strawman below.
        return {k: (v.copy() if hasattr(v, "copy") else v)
                for k, v in s.items()}

    def _timed(fn):
        # Warm once (tracing + per-pair program compile are one-time
        # costs a live service amortizes across replans), then time.
        jax.block_until_ready(fn(_copy(state))["flat"])
        s = _copy(state)
        jax.block_until_ready(s["flat"])
        t0 = time.perf_counter()
        out_state = fn(s)
        jax.block_until_ready(out_state["flat"])
        return time.perf_counter() - t0

    t_mig = _timed(lambda s: migrate_flat_state(s, plan_a, plan_b))
    moved = migration_bytes(plan_a, plan_b)
    out.append(("table3/measured_migration_s", f"{t_mig:.4f}",
                f"{moved / 1e6:.1f} MB of master+moments crossed shards "
                f"({plan_a.n_shards}->{plan_b.n_shards} aggregators); "
                f"full-gather path"))

    # Same transition through the O(moved-bytes) delta path (the shipped
    # ServiceRuntime default; benchmarks/migration_scaling.py sweeps it).
    delta = compile_migration_delta(plan_a, plan_b)
    t_delta = _timed(lambda s: migrate_flat_state_delta(
        s, plan_a, plan_b, delta=delta))
    out.append(("table3/measured_migration_delta_s", f"{t_delta:.4f}",
                f"delta path: {len(delta.moves)} move + {len(delta.zeros)} "
                f"zero runs, {delta.moved_bytes() / 1e6:.1f} MB moved "
                f"({t_mig / max(t_delta, 1e-9):.1f}x vs full gather)"))

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        save_ps_checkpoint(d, 0, plan_a, state)
        _, restored = restore_ps_checkpoint(d, 0, plan=plan_b)
        jax.block_until_ready(restored["flat"])
        t_ckpt = time.perf_counter() - t0
    out.append(("table3/measured_ckpt_restart_s", f"{t_ckpt:.4f}",
                f"migration is {t_ckpt / max(t_mig, 1e-9):.1f}x cheaper"))
    return out
