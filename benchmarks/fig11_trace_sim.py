"""Fig. 11: trace-driven simulation at cluster scale (Philly-like trace).

Paper: over 99% of samples allocated/required < 1; overall CPU-time saving
52.7%. Trace statistics documented in repro.sim.trace."""

import numpy as np

from repro.sim import ClusterSimulator, SimConfig, philly_like_trace

N_JOBS = 400


def rows(n_jobs: int = N_JOBS, seed: int = 1):
    trace = philly_like_trace(n_jobs=n_jobs, seed=seed)
    sim = ClusterSimulator(SimConfig(n_clusters=4))
    res = sim.run(trace)
    r = np.array(res.ratio_series())
    return [
        ("fig11/cpu_time_saving", f"{res.cpu_time_saving:.3f}", "paper: 0.527"),
        ("fig11/ratio_below_1", f"{(r < 1).mean():.3f}", "paper: >0.99"),
        ("fig11/ratio_max", f"{r.max():.2f}", "paper: worst >2.5"),
        ("fig11/max_loss", f"{res.max_loss_seen:.3f}", "LossLimit=0.1"),
        ("fig11/jobs_completed", str(res.n_jobs_done), f"trace n={n_jobs}"),
    ]
