"""Fig. 11: trace-driven simulation at cluster scale (Philly-like trace).

Paper: over 99% of samples allocated/required < 1; overall CPU-time saving
52.7%. Trace statistics documented in repro.sim.trace.

A second pass re-runs the trace with service-tick accounting enabled
(SimConfig.tick_interval): while J jobs are resident, per-job step
functions would execute one update pass per push, but the tick engine
(repro.ps.engine) drains one pending push per job per tick round -- the
batching-factor rows quantify how many per-job passes each batched pass
replaces at cluster scale.
"""

import numpy as np

from repro.sim import ClusterSimulator, SimConfig, philly_like_trace

N_JOBS = 400
TICK_INTERVAL = 60.0  # one service tick per Fig.-11 sample interval


def rows(n_jobs: int = N_JOBS, seed: int = 1):
    trace = philly_like_trace(n_jobs=n_jobs, seed=seed)
    # ONE simulation serves both row groups: tick_interval only adds
    # accounting in record_interval, it never changes placement/scaling,
    # so the allocation rows are identical with or without it.
    tick = ClusterSimulator(SimConfig(
        n_clusters=4, tick_interval=TICK_INTERVAL,
    )).run(trace)
    res = tick
    r = np.array(res.ratio_series())
    out = [
        ("fig11/cpu_time_saving", f"{res.cpu_time_saving:.3f}", "paper: 0.527"),
        ("fig11/ratio_below_1", f"{(r < 1).mean():.3f}", "paper: >0.99"),
        ("fig11/ratio_max", f"{r.max():.2f}", "paper: worst >2.5"),
        ("fig11/max_loss", f"{res.max_loss_seen:.3f}", "LossLimit=0.1"),
        ("fig11/jobs_completed", str(res.n_jobs_done), f"trace n={n_jobs}"),
    ]
    out += [
        ("fig11/tick_batching_factor", f"{tick.tick_batching_factor:.2f}",
         f"sequential per-job passes replaced per batched service tick "
         f"(tick_interval={TICK_INTERVAL:.0f}s)"),
        ("fig11/update_passes_sequential",
         f"{tick.update_passes_sequential:.0f}",
         "one pass per push: per-job step-function execution"),
        ("fig11/update_passes_batched",
         f"{tick.update_passes_batched:.0f}",
         "one pass per tick round: engine execution"),
        ("fig11/tick_limited_job_seconds",
         f"{tick.tick_limited_job_seconds:.0f}",
         "job-seconds with pushes tick-limited (one push per tick)"),
    ]
    return out
