"""Hot-path step cost vs co-residency: precompiled index maps + block-owned
update vs the pre-refactor data plane.

The paper's claim is that SHARED packed aggregation is cheap; the old data
plane contradicted it operationally: a job's step emitted one slice / zero
chunk per CO-RESIDENT segment for pull/push (O(total segments) HLO ops --
compile-time blowup for many-leaf models) and its masked Adam touched
every co-resident job's lanes (O(total space) update work).  This
benchmark holds ONE job fixed, scales (a) co-resident jobs and (b) leaves
per job, and compares three data planes for the fixed job's step:

  legacy  pre-refactor reference, copied here: per-segment slice+concat
          pull/push, full-space masked Adam
  masked  new index-map pull/push (one gather / one scatter), but still
          the full-space masked update (update_mode="masked")
  block   the shipped path: index maps + block-owned O(job-bytes) update

Metrics: HLO op count of the compiled step (O(segments) -> O(1)), wall
time per donated jitted step, exact update-path bytes from the plan
(7 passes x touched lanes: O(total space) -> O(job bytes)), and compile
time for many-leaf jobs.

Smoke mode (``HOTPATH_SMOKE=1`` or ``run.py --smoke``) shrinks the sweep
for CI.  ``run.py --only hotpath --json`` writes the rows to
BENCH_hotpath.json to seed the perf trajectory.
"""

from __future__ import annotations

import os
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.ps.plan import TensorSpec, compile_service_plan, segment_mask
from repro.ps.runtime import (
    _adam_math,
    _leaf_key,
    init_shared_state,
    make_ps_train_step,
    seed_job_params,
)


def _smoke() -> bool:
    return os.environ.get("HOTPATH_SMOKE", "") not in ("", "0")


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


def _job_tree(seed: int, n_leaves: int, leaf: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    return {f"t{i:03d}": jax.random.normal(k, (leaf,))
            for i, k in enumerate(ks)}


def _shared_plan(trees, n_shards: int = 2, pad_to: int = 128):
    """Compile a multi-job plan from stub Aggregators (control-plane-free:
    the benchmark measures the data plane, not Pseudocode 1)."""
    aggs = [SimpleNamespace(tasks={}, agg_id=f"agg{s}")
            for s in range(n_shards)]
    specs = {}
    for j, (jid, tree) in enumerate(sorted(trees.items())):
        specs[jid] = {}
        for t, (key, leaf) in enumerate(sorted(tree.items())):
            spec = TensorSpec(key, tuple(leaf.shape), leaf.dtype)
            specs[jid][t] = spec
            aggs[(j + t) % n_shards].tasks[(jid, t)] = SimpleNamespace(
                name=key, nbytes=spec.size * 4)
    return compile_service_plan(aggs, specs, pad_to=pad_to)


def _build(n_jobs: int, n_leaves: int, leaf: int):
    trees = {f"j{i}": _job_tree(i, n_leaves, leaf) for i in range(n_jobs)}
    plan = _shared_plan(trees)
    state = init_shared_state(plan)
    for jid, tree in sorted(trees.items()):
        state = seed_job_params(plan, state, jid, tree)
    tree0 = trees["j0"]
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree0)
    batch = {"target": jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree0)}
    return plan, state, abstract, batch


# ------------------------------------------- pre-refactor reference step
def _legacy_unflatten(plan, flat, abstract, job_id):
    """Pre-refactor pull: one strided slice per segment of the plan."""
    out_by_key = {}
    for seg in plan.segments:
        if seg.job_id != job_id:
            continue
        start = plan.start(seg)
        out_by_key[seg.key] = jax.lax.slice(
            flat, (start,), (start + seg.size,)
        ).reshape(seg.shape).astype(seg.dtype)
    leaves, _ = jax.tree_util.tree_flatten_with_path(abstract)
    ordered = [out_by_key[_leaf_key(path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract), ordered)


def _legacy_flatten(plan, tree, dtype, job_id):
    """Pre-refactor push: one part per CO-RESIDENT segment (zeros for the
    other jobs' lanes), then one giant concatenate."""
    by_key = {
        _leaf_key(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }
    parts = []
    pos = 0
    for shard_idx in plan.shard_segments:
        for i in shard_idx:
            seg = plan.segments[i]
            start = plan.start(seg)
            if start > pos:  # job-run alignment gap in the new layouts
                parts.append(jnp.zeros((start - pos,), dtype))
            if seg.job_id != job_id:
                parts.append(jnp.zeros((seg.size,), dtype))
            else:
                parts.append(by_key[seg.key].reshape(-1).astype(dtype))
            pos = start + seg.size
    if pos < plan.total_len:
        parts.append(jnp.zeros((plan.total_len - pos,), dtype))
    return jnp.concatenate(parts)


def _legacy_step(plan, abstract, job_id, lr=0.05):
    mask = jnp.asarray(segment_mask(plan, job_id))

    def step(state, batch):
        flat = state["flat"]
        params = _legacy_unflatten(plan, flat, abstract, job_id)
        loss, grads = jax.value_and_grad(_loss)(params, batch)
        gflat = _legacy_flatten(plan, grads, jnp.float32, job_id)
        count = state["counts"][job_id] + 1
        new_flat, mu, nu = _adam_math(
            flat, gflat, state["mu"], state["nu"], count,
            lr=lr, b1=0.9, b2=0.999, eps=1e-8)
        new_state = dict(state)
        new_state["flat"] = jnp.where(mask, new_flat, flat)
        new_state["mu"] = jnp.where(mask, mu, state["mu"])
        new_state["nu"] = jnp.where(mask, nu, state["nu"])
        new_state["counts"] = dict(state["counts"], **{job_id: count})
        return new_state, {"loss": loss}

    return step


def _make_step(plan, abstract, mode):
    if mode == "legacy":
        return _legacy_step(plan, abstract, "j0")
    return make_ps_train_step(_loss, plan, abstract, lr=0.05, job_id="j0",
                              update_mode=mode)


def _hlo_op_count(text: str) -> int:
    return sum(1 for line in text.splitlines() if " = " in line)


def _measure(plan, state, abstract, batch, mode: str, repeats: int):
    step = _make_step(plan, abstract, mode)
    compiled = jax.jit(step).lower(state, batch).compile()
    text = compiled.as_text()
    # Timed exactly as the runtime runs it: donated, state threaded
    # through.  Donation consumes buffers, so thread a private copy.
    timed = jax.jit(step, donate_argnums=(0,))
    s, _ = timed(jax.tree_util.tree_map(jnp.array, state), batch)  # warmup
    jax.block_until_ready(s["flat"])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        s, _ = timed(s, batch)
        jax.block_until_ready(s["flat"])
        best = min(best, time.perf_counter() - t0)
    if mode == "block":
        touched = plan.job_layout("j0").packed_len
    else:
        touched = plan.total_len  # full-space masked update
    return {
        "hlo_ops": _hlo_op_count(text),
        # p/mu/nu read+write plus the gradient read, 4 B/lane: the bytes
        # the UPDATE path touches (exact, from the plan -- the HLO cost
        # model while-loop-multiplies XLA:CPU's scatter lowering).
        "touched_kb": 7 * touched * 4 / 1e3,
        "step_ms": best * 1e3,
    }


MODES = ("legacy", "masked", "block")


def rows():
    smoke = _smoke()
    co_resident = (1, 2) if smoke else (1, 2, 4, 8)
    leaves_sweep = (16,) if smoke else (64, 256)
    base_leaves = 8 if smoke else 16
    leaf = 64 if smoke else 2048
    repeats = 3 if smoke else 10
    out = []

    # -- axis (a): co-resident jobs share the space; job j0 is fixed -------
    for n_jobs in co_resident:
        plan, state, abstract, batch = _build(n_jobs, base_leaves, leaf)
        n_segments = len(plan.segments)
        for mode in MODES:
            m = _measure(plan, state, abstract, batch, mode, repeats)
            tag = f"{mode}/jobs{n_jobs}"
            ctx = (f"{n_segments} co-resident segments, "
                   f"total space {plan.total_len}")
            out.append((f"hotpath/hlo_ops/{tag}", m["hlo_ops"], ctx))
            out.append((f"hotpath/step_ms/{tag}", f"{m['step_ms']:.3f}",
                        f"donated jitted step, best of {repeats}"))
            out.append((f"hotpath/update_touched_kb/{tag}",
                        f"{m['touched_kb']:.1f}",
                        "update-path bytes: 7 passes x touched lanes x 4 B"))

    # -- acceptance summary: step cost flat in total space -----------------
    def _series(metric, mode):
        return [v for (name, v, _) in out
                if name.startswith(f"hotpath/{metric}/{mode}/")]

    ops = {m: [int(v) for v in _series("hlo_ops", m)] for m in MODES}
    # jobs=1 is the covers_all identity fast path (fewer ops still); the
    # O(1)-in-segments claim is judged across the shared (>=2 jobs) runs.
    shared_block = ops["block"][1:] or ops["block"]
    out.append((
        "hotpath/hlo_ops_o1_in_segments",
        int(max(shared_block) <= 1.05 * shared_block[0]
            and ops["legacy"][-1] > ops["legacy"][0]),
        f"block {ops['block']} flat; legacy {ops['legacy']} grows across "
        f"{co_resident} co-resident jobs",
    ))
    ms = {m: [float(v) for v in _series("step_ms", m)] for m in MODES}
    out.append((
        "hotpath/step_ms_summary",
        f"{ms['block'][-1]:.3f}",
        f"block {ms['block']} vs masked {ms['masked']} vs legacy "
        f"{ms['legacy']} across {co_resident} co-resident jobs",
    ))
    kb = {m: [float(v) for v in _series("update_touched_kb", m)]
          for m in MODES}
    out.append((
        "hotpath/update_bytes_o_job",
        int(max(kb["block"]) <= 1.10 * kb["block"][0]),
        f"block touches {kb['block']} kB (~O(job bytes), flat); masked/"
        f"legacy touch {kb['masked']} kB (O(total space))",
    ))

    # -- axis (b): many-leaf models under co-residency (compile blowup) ----
    # The legacy push emits one HLO chunk per CO-RESIDENT segment (jobs x
    # leaves), so tracing+compile blows up with either axis; the new paths
    # stay O(own leaves).
    compile_jobs = 2 if smoke else 8
    for n_leaves in leaves_sweep:
        plan, state, abstract, batch = _build(compile_jobs, n_leaves, 128)
        for mode in MODES:
            step = _make_step(plan, abstract, mode)
            t0 = time.perf_counter()
            compiled = jax.jit(step).lower(state, batch).compile()
            compile_s = time.perf_counter() - t0
            out.append((
                f"hotpath/compile_ms/{mode}/jobs{compile_jobs}-"
                f"leaves{n_leaves}",
                f"{compile_s * 1e3:.0f}",
                f"{len(plan.segments)} segments, "
                f"{_hlo_op_count(compiled.as_text())} HLO ops",
            ))
    return out


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
