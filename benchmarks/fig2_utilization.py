"""Fig. 2: average CPU utilization of dedicated parameter servers."""

from repro.configs.paper_workloads import standalone_utilization

CASES = [("alexnet", 1, 2), ("vgg19", 1, 2), ("awd-lm", 1, 2), ("bert", 1, 2),
         ("alexnet", 2, 2), ("vgg19", 2, 2), ("awd-lm", 2, 2), ("bert", 2, 2)]


def rows():
    out = []
    for model, s, w in CASES:
        u = standalone_utilization(model, s, w)
        out.append((f"fig2/util/{model}-{s}s-{w}w", f"{u:.3f}",
                    "paper: VGG19 1s-2w ~= 0.16; >half CPU unused"))
    return out
