"""Fault tolerance: snapshot overhead, bounded-rollback recovery, and
MTTR of shard-loss recovery (PR 7).

Three sections, all on a REAL :class:`ShardedServiceRuntime` +
:class:`ShardedTickEngine` with a seeded :class:`FaultInjector`:

* ``snapshot``: per-tick cost of the last-good snapshot protocol --
  identical workloads run with ``snapshot_interval=0`` (disabled), the
  default ``8``, and the worst case ``1`` (copy every tick).  The
  acceptance row asserts the default interval costs <= 10% of tick time.

* ``transient``: a transient injected apply failure on one shard at
  ``max_staleness=0``.  The lane rolls back to its snapshot and replays;
  the trajectory must end bit-exact vs a fault-free twin stepping the
  identical batches, with ZERO forced quiesces (no replan, no fleet
  disruption) and every co-resident job ticking straight through.

* ``mttr``: a shard killed outright (every apply fails).  The lane
  quarantines after its retry budget; jobs NOT hosted on the dead shard
  keep stepping while it is down; ``recover_shard`` re-hosts the dead
  shard's segments on the survivors.  MTTR is wall clock from the first
  quarantine surfacing to the post-recovery fleet fully draining again.

Run: PYTHONPATH=src python benchmarks/run.py --only recovery \
         --json BENCH_recovery.json
"""

import os
import time

SNAPSHOT_INTERVAL = 8  # the engines' default


def _smoke() -> bool:
    return bool(os.environ.get("HOTPATH_SMOKE"))


def _build(n_shards=3, **engine_opts):
    """Service + sharded runtime + engine with 3 jobs over n_shards."""
    import jax
    import jax.numpy as jnp

    from repro.core import ParameterService
    from repro.ps.service_runtime import ShardedServiceRuntime

    def tree(key, sizes):
        ks = jax.random.split(key, len(sizes))
        return {f"t{i}": jax.random.normal(k, (n,))
                for i, (k, n) in enumerate(zip(ks, sizes))}

    def loss(params, batch):
        return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
                   for k in params)

    trees = {
        "a": tree(jax.random.PRNGKey(0), (96, 32, 64)),
        "b": tree(jax.random.PRNGKey(1), (64, 32)),
        "c": tree(jax.random.PRNGKey(2), (48, 16)),
    }
    targets = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
               for j, t in trees.items()}

    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(max_staleness=0, jit=False, **engine_opts)
    for jid, t in trees.items():
        nb = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, loss, lr=0.05, required_servers=1,
                   agg_throughput=nb / 0.2)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng, targets


def _run_steps(eng, targets, n):
    for _ in range(n):
        for j in targets:
            eng.step(j, {"target": targets[j]})
    eng.drain()


def _snapshot_rows():
    n_steps = 40 if _smoke() else 200
    repeats = 2 if _smoke() else 3

    def timed(interval):
        best = float("inf")
        for _ in range(repeats):
            rt, eng, targets = _build(snapshot_interval=interval)
            _run_steps(eng, targets, 5)  # warm the appliers
            t0 = time.perf_counter()
            _run_steps(eng, targets, n_steps)
            best = min(best, time.perf_counter() - t0)
        return best / n_steps * 1e3  # ms per step round

    t_off = timed(0)
    t_default = timed(SNAPSHOT_INTERVAL)
    t_every = timed(1)
    overhead = (t_default - t_off) / t_off * 100.0
    return [
        ("recovery/tick_ms_no_snapshot", f"{t_off:.3f}",
         "3-job step round, snapshot_interval=0 (rollback disabled)"),
        ("recovery/tick_ms_snapshot_default", f"{t_default:.3f}",
         f"same workload, snapshot_interval={SNAPSHOT_INTERVAL} "
         f"(the default)"),
        ("recovery/tick_ms_snapshot_every", f"{t_every:.3f}",
         "worst case: last-good copy EVERY tick (interval=1)"),
        ("recovery/snapshot_overhead_pct", f"{overhead:.1f}",
         "default-interval overhead vs snapshots disabled"),
        ("recovery/snapshot_overhead_ok", str(int(overhead <= 10.0)),
         "acceptance: snapshot protocol costs <= 10% of tick time at "
         "the default interval"),
    ]


def _transient_rows():
    import numpy as np

    from repro.ps.faults import FaultInjector

    n_steps = 12 if _smoke() else 30
    inj = FaultInjector(seed=7)
    rt, eng, targets = _build(snapshot_interval=SNAPSHOT_INTERVAL,
                              fault_injector=inj)
    twin, teng, _ = _build(snapshot_interval=SNAPSHOT_INTERVAL)
    victim = rt.shard_ids[-1]
    inj.fail_apply(victim, at=4).fail_apply(victim, at=9)

    _run_steps(eng, targets, n_steps)
    _run_steps(teng, targets, n_steps)

    mismatches = 0
    for j in targets:
        p, q = rt.params_of(j), twin.params_of(j)
        for k in p:
            if not np.array_equal(np.asarray(p[k]), np.asarray(q[k])):
                mismatches += 1
    return [
        ("recovery/transient_faults_fired", str(inj.n_fired),
         f"injected apply failures on {victim!r} (seeded schedule)"),
        ("recovery/transient_rollbacks", str(eng.stats.n_rollbacks),
         "snapshot restores that recovered a failed apply in place"),
        ("recovery/transient_replayed", str(eng.stats.n_replayed),
         "applied pushes re-queued and re-applied by those rollbacks"),
        ("recovery/transient_forced_quiesces",
         str(eng.stats.n_forced_replan),
         "acceptance: rollback recovery forces NO replan quiesce on "
         "any job (must be 0)"),
        ("recovery/transient_quarantines", str(eng.stats.n_quarantines),
         "lanes lost to the transient faults (must be 0)"),
        ("recovery/transient_bit_exact", str(int(mismatches == 0)),
         "acceptance: post-recovery s=0 trajectory vs fault-free twin, "
         "bit-exact (must be 1)"),
    ]


def _mttr_rows():
    from repro.ps.faults import EngineQuarantinedError, FaultInjector

    n_down_steps = 5 if _smoke() else 20
    inj = FaultInjector(seed=11)
    rt, eng, targets = _build(snapshot_interval=SNAPSHOT_INTERVAL,
                              fault_injector=inj)
    victim = rt.shard_ids[-1]
    inj.kill_shard(victim, at=3)

    # Step until the kill surfaces as a quarantine.
    t_fail = None
    for _ in range(200):
        try:
            for j in targets:
                eng.step(j, {"target": targets[j]})
        except EngineQuarantinedError:
            t_fail = time.perf_counter()
            break
    assert t_fail is not None, "kill never quarantined the lane"

    # Degraded operation: jobs not hosted on the dead shard keep going.
    untouched = [j for j in targets
                 if victim not in rt.splan.job_layout(j).shard_ids]
    survivor_steps = 0
    for _ in range(n_down_steps):
        for j in untouched:
            eng.step(j, {"target": targets[j]})
            survivor_steps += 1

    report = rt.recover_shard(victim)
    _run_steps(eng, targets, 3)  # fleet healthy again, all jobs
    mttr_ms = (time.perf_counter() - t_fail) * 1e3
    return [
        ("recovery/killed_shard", victim,
         "shard killed by the injector (every apply fails)"),
        ("recovery/survivor_steps_while_down", str(survivor_steps),
         "steps jobs off the dead shard completed during the outage "
         "(graceful degradation; > 0)"),
        ("recovery/seeded_from", report.seeded_from,
         "where the re-hosted segments' values came from"),
        ("recovery/rolled_back_pushes", str(report.rolled_back_pushes),
         f"applied pushes discarded with the lost lane (bounded by the "
         f"snapshot interval, {SNAPSHOT_INTERVAL})"),
        ("recovery/cancelled_pushes", str(report.cancelled_pushes),
         "pending pushes that could never apply (futures raise)"),
        ("recovery/rehosted_elements", str(report.rehosted_elements),
         "payload elements migrated onto the surviving fleet"),
        ("recovery/mttr_ms", f"{mttr_ms:.1f}",
         "first quarantine surfacing -> recovered fleet fully draining "
         "(includes the degraded-operation window)"),
        ("recovery/mttr_finite",
         str(int(0.0 < mttr_ms < float("inf"))),
         "acceptance: a killed shard is recoverable in finite time via "
         "recover_shard (must be 1)"),
        ("recovery/rollback_bounded", str(int(
            report.rolled_back_pushes <= SNAPSHOT_INTERVAL * len(targets))),
         "acceptance: rollback window bounded by snapshot_interval "
         "ticks of pushes (must be 1)"),
    ]


def rows():
    return _snapshot_rows() + _transient_rows() + _mttr_rows()


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
