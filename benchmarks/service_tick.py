"""Service-tick batching: per-job sequential steps vs one batched pass.

The paper's aggregation service packs many jobs' bursty pushes onto shared
CPUs; PR 3's tick engine (repro.ps.engine) executes them together.  This
benchmark seeds K co-resident jobs into one compiled shared plan,
pre-packs one pending gradient push per job, and times the APPLY path two
ways through the same engine:

  sequential  K single-job ticks (submit job j's push, tick, repeat):
              exactly the PR-2 per-job block-step update, one jitted
              gather+Adam+scatter program per job
  batched     one tick with all K pushes pending: ONE fused pass over the
              concatenated owned-block table (single Pallas launch on
              TPU, fused-scatter jnp pass in interpret mode)

Both paths apply identical pushes to identical states (bit-exact at the
shipped block_align; see tests/test_engine.py), so the only difference is
execution shape.  The engine dispatches per-job passes below its
``min_batch_jobs`` crossover (this benchmark measured the one-launch
concatenation LOSING at 2 jobs before that knob existed) and the fused
pass above it, so the tick must never lose to K per-job passes at ANY
K and must win outright at max co-residency -- that is the acceptance
row ``service_tick/tick_never_loses``.

PR 6 adds the FLEET sweep: the same K jobs sharded over S Aggregator
spaces, timing one all-pending round of the sharded engine both ways --
``fleet_tick="fused"`` (ONE launch over the lanes' concatenated states)
vs ``"per_shard"`` (one launch group per lane).  The acceptance row
``service_tick/fleet_tick_flat_scaling`` asserts the fused per-tick wall
time stays ~flat (<= 1.3x) as the fleet grows 1 -> 4 shards, where the
per-shard loop pays one dispatch per lane.

Smoke mode (``SERVICE_TICK_SMOKE=1``/``HOTPATH_SMOKE=1`` or ``--smoke``)
shrinks the sweep for CI.  ``run.py --only service_tick --json
BENCH_service_tick.json`` seeds the perf-trajectory file.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ParameterService
from repro.ps.runtime import _pack_slots
from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime

JOB_COUNTS = (2, 4, 8)
FLEET_SIZES = (1, 2, 4)


def _smoke() -> bool:
    return any(os.environ.get(k, "") not in ("", "0")
               for k in ("SERVICE_TICK_SMOKE", "HOTPATH_SMOKE"))


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


def _job_tree(seed: int, n_leaves: int, leaf: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    return {f"t{i:03d}": jax.random.normal(k, (leaf,))
            for i, k in enumerate(ks)}


def _build(n_jobs: int, n_leaves: int, leaf: int):
    """K quad jobs in ONE service; returns (runtime, per-job grad trees)."""
    svc = ParameterService(total_budget=64, n_clusters=1, plan_pad_to=128)
    rt = ServiceRuntime(svc)
    trees = {f"j{i}": _job_tree(i, n_leaves, leaf) for i in range(n_jobs)}
    for jid, tree in sorted(trees.items()):
        nbytes = sum(4 * v.size for v in tree.values())
        rt.add_job(jid, tree, _loss, lr=0.05, required_servers=2,
                   agg_throughput=nbytes / 0.4)
    grads = {jid: jax.tree_util.tree_map(
        lambda x: jnp.ones_like(x) * 0.01, tree)
        for jid, tree in trees.items()}
    return rt, grads


def _time_ticks(rt, grads, batched: bool, repeats: int) -> float:
    """Wall time to apply one pre-packed pending push of EVERY job, best
    of repeats -- times the tick/apply path only (gradient packing is done
    once up front, identically for both modes).

    batched=True: all pushes pending -> one tick (one fused pass).
    batched=False: enqueue+tick per job -> K single-job passes (the PR-2
    per-job block-step update, driven through the same engine plumbing).
    """
    eng = rt.engine
    jobs = sorted(grads)
    packed = {}
    for jid in jobs:
        layout = rt.plan.job_layout(jid)
        packed[jid] = jax.block_until_ready(
            _pack_slots(layout, grads[jid]))

    def run_round():
        if batched:
            for jid in jobs:
                eng.submit_packed(jid, packed[jid])
            eng.tick()
        else:
            for jid in jobs:
                eng.submit_packed(jid, packed[jid])
                eng.tick()
        jax.block_until_ready(rt.state["flat"])

    run_round()  # warmup: compiles the appliers
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_round()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _build_fleet(n_shards: int, n_jobs: int, leaf: int):
    """K single-tensor jobs on a SHARDED runtime scaled to n_shards.

    SINGLE-tensor jobs on purpose: a segment lives wholly in one shard,
    so a job never fragments as the fleet splits -- the fused fleet
    launch runs the SAME per-entry table at every S and the sweep
    isolates dispatch cost (one launch vs one per lane), not placement
    fragmentation.  Per-job load is sized so the base placement packs
    everything onto ONE Aggregator (the sweep then really measures
    1 -> S scaling).
    """
    svc = ParameterService(total_budget=64, n_clusters=1, plan_pad_to=128)
    rt = ShardedServiceRuntime(svc)
    trees = {f"j{i}": _job_tree(i, 1, leaf) for i in range(n_jobs)}
    for jid, tree in sorted(trees.items()):
        nbytes = sum(4 * v.size for v in tree.values())
        rt.add_job(jid, tree, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / (0.8 / n_jobs))
    if n_shards > 1:
        rt.service.scale_out(n_shards - 1)
    grads = {jid: jax.tree_util.tree_map(
        lambda x: jnp.ones_like(x) * 0.01, tree)
        for jid, tree in trees.items()}
    return rt, grads


def _time_fleet_ticks(rt, eng, grads, mode: str, repeats: int) -> float:
    """Wall time of ONE all-pending round of the sharded engine in the
    given fleet_tick mode, best of repeats.  Pushes are enqueued OUTSIDE
    the timed region (identically for both modes), so the timer sees only
    the tick/apply path -- the dispatch shape under test."""
    eng.fleet_tick = mode
    jobs = sorted(grads)

    def enqueue():
        for jid in jobs:
            eng.submit_push(jid, grads[jid])
        for st in rt.states.values():
            jax.block_until_ready(st["flat"])

    enqueue()
    eng.tick()  # warmup: compiles this mode's appliers
    best = float("inf")
    for _ in range(repeats):
        enqueue()
        t0 = time.perf_counter()
        eng.tick()
        for st in rt.states.values():
            jax.block_until_ready(st["flat"])
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _fleet_rows(smoke: bool):
    n_jobs = 4 if smoke else 8
    leaf = 256 if smoke else 1024
    repeats = 3 if smoke else 25
    sizes = FLEET_SIZES[:-1] if smoke else FLEET_SIZES
    out = []
    fused_ms, per_shard_ms = {}, {}
    for want in sizes:
        rt, grads = _build_fleet(want, n_jobs, leaf)
        eng = rt.attach_engine(max_staleness=0, queue_capacity=1)
        s = rt.n_shards  # the packing may refuse a requested split
        if s in fused_ms:
            continue
        # Launch accounting sanity: one fused round = ONE launch, one
        # per-shard round = one launch group per pending lane.
        eng.fleet_tick = "fused"
        for jid in sorted(grads):
            eng.submit_push(jid, grads[jid])
        before = eng.stats.n_launches
        eng.tick()
        assert eng.stats.n_launches == before + 1, "fleet tick must be 1 launch"
        fused_ms[s] = _time_fleet_ticks(rt, eng, grads, "fused", repeats)
        per_shard_ms[s] = _time_fleet_ticks(rt, eng, grads, "per_shard",
                                            repeats)
        ctx = (f"{n_jobs} single-tensor jobs ({leaf} lanes each) over "
               f"{s} shard spaces")
        out.append((f"service_tick/fleet_fused_ms/shards{s}",
                    f"{fused_ms[s]:.3f}",
                    f"ONE fused launch per round; {ctx}"))
        out.append((f"service_tick/fleet_per_shard_ms/shards{s}",
                    f"{per_shard_ms[s]:.3f}",
                    f"one launch group per lane per round; {ctx}"))
        out.append((f"service_tick/fleet_speedup/shards{s}",
                    f"{per_shard_ms[s] / fused_ms[s]:.2f}",
                    f"per-shard round / fused round at {s} shards"))
    lo, hi = min(fused_ms), max(fused_ms)
    flat_ok = hi > lo and fused_ms[hi] <= 1.3 * fused_ms[lo]
    out.append((
        "service_tick/fleet_tick_flat_scaling",
        int(flat_ok),
        f"acceptance: fused per-tick wall time ~flat as the fleet grows "
        f"{lo} -> {hi} shards "
        f"({fused_ms[lo]:.3f} -> {fused_ms[hi]:.3f} ms, <= 1.3x) while "
        f"per_shard pays per-lane dispatch "
        f"({per_shard_ms[lo]:.3f} -> {per_shard_ms[hi]:.3f} ms)",
    ))
    return out


def rows():
    smoke = _smoke()
    n_leaves = 8 if smoke else 16
    # Bursty-small regime (the paper's scenario: many KB-to-MB aggregation
    # tasks sharing CPUs) -- where batching K dispatches into one pass
    # shows up clearly over the elementwise work itself.
    leaf = 64 if smoke else 256
    repeats = 3 if smoke else 25
    out = []
    seq_ms, bat_ms = {}, {}
    dispatch_per_job = {}
    crossover = None  # the engine default (captured from the instance)
    for n_jobs in JOB_COUNTS:
        rt, grads = _build(n_jobs, n_leaves, leaf)
        eng = rt.attach_engine(max_staleness=0, queue_capacity=1)
        crossover = eng.min_batch_jobs
        # More repeats at small K: those rounds are sub-ms and noisier.
        reps = repeats * (JOB_COUNTS[-1] // n_jobs)
        seq_ms[n_jobs] = _time_ticks(rt, grads, batched=False, repeats=reps)
        bat_ms[n_jobs] = _time_ticks(rt, grads, batched=True, repeats=reps)
        # Did the all-pending tick route through the small-K per-job
        # dispatch (below min_batch_jobs) or the fused pass?
        dispatch_per_job[n_jobs] = eng.stats.n_per_job_dispatch > 0
        ctx = (f"{n_jobs} jobs x {n_leaves} leaves x {leaf} lanes, "
               f"space {rt.plan.total_len}")
        out.append((f"service_tick/sequential_ms/jobs{n_jobs}",
                    f"{seq_ms[n_jobs]:.3f}",
                    f"K single-job passes per round; {ctx}"))
        out.append((f"service_tick/batched_ms/jobs{n_jobs}",
                    f"{bat_ms[n_jobs]:.3f}",
                    f"ONE fused pass per round; {ctx}"))
        out.append((f"service_tick/speedup/jobs{n_jobs}",
                    f"{seq_ms[n_jobs] / bat_ms[n_jobs]:.2f}",
                    f"{n_jobs} per-job passes replaced by one batched tick"))

    # Acceptance: with the measured-crossover dispatch (min_batch_jobs)
    # one engine tick never loses to K per-job passes at ANY K -- below
    # the crossover it runs the same per-job passes with one tick's
    # bookkeeping, above it the fused one-launch pass takes over -- and
    # it must win outright at max co-residency.  Below the crossover the
    # two modes execute the SAME per-job programs, so their sub-ms wall
    # times differ only by scheduler noise -- the acceptance there is
    # STRUCTURAL (the per-job dispatch really engaged, so the old fused
    # small-K loss cannot recur); at max K the fused win is large enough
    # to assert on wall clock.
    k1 = JOB_COUNTS[-1]
    crossover_ok = all(
        dispatch_per_job[k] == (k < crossover) for k in JOB_COUNTS)
    out.append((
        "service_tick/tick_never_loses",
        int(crossover_ok and bat_ms[k1] < seq_ms[k1]),
        f"per-job dispatch at {[k for k in JOB_COUNTS if dispatch_per_job[k]]} "
        f"jobs (crossover), fused above; tick/sequential ratios "
        f"{[round(bat_ms[k] / seq_ms[k], 2) for k in JOB_COUNTS]}; tick "
        f"wins {seq_ms[k1] / bat_ms[k1]:.2f}x at {k1} jobs",
    ))
    out.append((
        "service_tick/per_tick_ms_summary",
        f"{bat_ms[k1]:.3f}",
        f"batched {[round(bat_ms[k], 3) for k in JOB_COUNTS]} vs sequential "
        f"{[round(seq_ms[k], 3) for k in JOB_COUNTS]} across {JOB_COUNTS} jobs",
    ))
    out.extend(_fleet_rows(smoke))
    return out


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        os.environ["SERVICE_TICK_SMOKE"] = "1"
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
