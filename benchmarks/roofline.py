"""Roofline table: reads the dry-run JSONs (results/dryrun) and prints the
per-(arch x shape x mesh) three-term roofline (EXPERIMENTS.md section
generator)."""

import glob
import json
from pathlib import Path

RESULTS = (Path("results/dryrun_final")
           if Path("results/dryrun_final").exists() else Path("results/dryrun"))


def load(mesh="pod256"):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / mesh / "*.json"))):
        rows.append(json.loads(Path(f).read_text()))
    return rows


def rows(mesh="pod256"):
    out = []
    for r in load(mesh):
        if not r.get("ok"):
            out.append((f"roofline/{mesh}/{r['arch']}/{r['shape']}", "FAIL",
                        r.get("error", "")[:80]))
            continue
        rf = r["roofline"]
        out.append((
            f"roofline/{mesh}/{r['arch']}/{r['shape']}",
            f"{r['roofline_fraction']:.4f}",
            f"dom={rf['dominant']} tc={rf['t_compute_s']:.3g}s "
            f"tm={rf['t_memory_s']:.3g}s tx={rf['t_collective_s']:.3g}s "
            f"peakGB={r['memory']['peak_estimate_bytes'] / 1e9:.1f} "
            f"useful={r['useful_flops_ratio']:.2f}",
        ))
    return out


def markdown_table(mesh="pod256"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "peak GB/dev | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.3g} | "
            f"{rf['t_memory_s']:.3g} | {rf['t_collective_s']:.3g} | "
            f"{rf['dominant']} | {r['memory']['peak_estimate_bytes'] / 1e9:.1f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.1%} |"
        )
    return "\n".join(lines)
