"""High-QPS read tier (PR 10): snapshot-published pull replicas with
batched lookup, on REAL engines (eager, CPU).

Four sections:

* ``parity`` (asserted BEFORE any timing): a replica-served pull --
  both the parameter pytree and the versioned full payload -- is
  bit-exact vs ``engine.pull()`` at the same published version, for
  every job, after a force-refresh publish.

* ``scaling``: pulls/sec vs replica count (1, 2, 4).  Each replica is
  an independent serving endpooint holding the same shared snapshots,
  so the aggregate rate is the sum of per-replica serve rates under a
  round-robin load (in-process, the replicas time-slice one CPU; the
  per-replica rate is what each endpoint sustains on its own core in a
  deployment).

* ``batch``: the batched lookup API.  8 jobs pulled from ONE replica as
  8 sequential versioned pulls vs one ``pull_batch`` (all jobs' changed
  rows in ONE jitted gather); the acceptance row asserts the batch is
  >= 2x faster.

* ``diff``: replica-served diff pulls must charge the same wire bytes
  as the engine's own diff accounting for the identical read schedule
  (same version vectors, same dirty blocks).

Run: PYTHONPATH=src python benchmarks/run.py --only read \
         --json BENCH_read.json
"""

import os
import time

BATCH_SPEEDUP_FLOOR = 2.0  # acceptance: pull_batch >= 2x sequential
N_JOBS = 8


def _smoke() -> bool:
    return bool(os.environ.get("HOTPATH_SMOKE"))


def _trees():
    import jax

    sizes = ((96, 32, 64), (64, 32), (48, 16), (80, 32), (64, 16),
             (48, 32, 16), (96, 16), (32, 32))

    def tree(key, ss):
        ks = jax.random.split(key, len(ss))
        return {f"t{i}": jax.random.normal(k, (n,))
                for i, (k, n) in enumerate(zip(ks, ss))}

    return {f"j{i}": tree(jax.random.PRNGKey(i), ss)
            for i, ss in enumerate(sizes[:N_JOBS])}


def _loss():
    import jax.numpy as jnp

    def loss(params, batch):
        return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
                   for k in params)

    return loss


def _build(n_shards=3, n_replicas=2, **replica_opts):
    """Sharded runtime + engine + attached ReplicaSet over N_JOBS jobs."""
    import jax

    from repro.core import ParameterService
    from repro.ps.replica import ReplicaSet
    from repro.ps.service_runtime import ShardedServiceRuntime

    trees = _trees()
    targets = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
               for j, t in trees.items()}
    svc = ParameterService(total_budget=32, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(max_staleness=0, jit=False)
    for jid, t in trees.items():
        nb = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss(), lr=0.05, required_servers=1,
                   agg_throughput=nb / 0.2)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    rs = ReplicaSet(eng, n_replicas=n_replicas, **replica_opts)
    return rt, eng, rs, targets


def _run_steps(eng, targets, n):
    for _ in range(n):
        for j in targets:
            eng.step(j, {"target": targets[j]})
    eng.drain()


def _assert_parity(eng, rs, targets) -> int:
    """Replica-served pulls bit-exact vs the engine at the same
    published version; returns jobs compared (raises on mismatch)."""
    import numpy as np

    rs.refresh()  # publish the CURRENT state: engine and replica now
    # serve the same version by construction
    checked = 0
    for j in targets:
        a, b = eng.pull(j), rs.pull(j)
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                raise AssertionError(
                    f"replica tree pull diverges from engine.pull "
                    f"for {j!r}/{k!r}")
        da = eng.pull(j, since_version=0)  # full payload, same version
        db = rs.pull(j, since_version=0)
        if not np.array_equal(np.asarray(da.data), np.asarray(db.data)):
            raise AssertionError(
                f"replica full payload diverges from the engine's "
                f"for {j!r}")
        if da.bytes_full != db.bytes_full:
            raise AssertionError(
                f"full-pull byte accounting diverges for {j!r}: "
                f"engine {da.bytes_full} vs replica {db.bytes_full}")
        checked += 1
    return checked


def _parity_rows():
    n_steps = 3 if _smoke() else 10
    rt, eng, rs, targets = _build()
    _run_steps(eng, targets, n_steps)
    checked = _assert_parity(eng, rs, targets)
    return [
        ("read/parity_jobs", str(checked),
         f"jobs compared bit-exact (tree + full payload) after "
         f"{n_steps} step rounds, replica vs engine.pull at the same "
         f"published version"),
        ("read/parity_bit_exact", "1",
         "acceptance: replica-served pulls match the engine exactly "
         "(asserted before any timing; must be 1)"),
    ]


def _scaling_rows():
    n_pulls = 120 if _smoke() else 600
    rows = []
    rates = {}
    for n_rep in (1, 2, 4):
        rt, eng, rs, targets = _build(n_replicas=n_rep)
        _run_steps(eng, targets, 2)
        rs.refresh()
        jobs = list(targets)
        for j in jobs:  # warm every replica's serve path
            for rep in rs.replicas:
                rep.pull(j)
        for rep in rs.replicas:  # count only the timed load below
            rep.stats.n_pulls = 0
            rep.stats.serve_seconds = 0.0
        for i in range(n_pulls):  # round-robin load over the set
            rs.pull(jobs[i % len(jobs)])
        # Aggregate = sum of per-replica serve rates: each replica is an
        # independent endpoint on its own copy-free snapshot view.
        agg = sum(rep.stats.n_pulls / max(rep.stats.serve_seconds, 1e-9)
                  for rep in rs.replicas)
        rates[n_rep] = agg
        rows.append((
            f"read/pulls_per_sec_{n_rep}r", f"{agg:.0f}",
            f"{n_pulls} tree pulls round-robin over {n_rep} replica(s), "
            f"summed per-endpoint serve rates"))
    scaling = rates[4] / rates[1]
    rows += [
        ("read/replica_scaling_4r_vs_1r", f"{scaling:.2f}",
         "aggregate pulls/sec at 4 replicas / at 1 replica"),
        ("read/replica_scaling_up", str(int(rates[4] > rates[1])),
         "acceptance: aggregate read rate grows with replica count "
         "(must be 1)"),
    ]
    return rows


def _batch_rows():
    rounds = 4 if _smoke() else 12
    rt, eng, rs, targets = _build(n_replicas=2)
    jobs = list(targets)
    _run_steps(eng, targets, 2)
    rs.refresh()
    seq_rep, bat_rep = rs.replicas[0], rs.replicas[1]
    # Bootstrap both readers' version vectors (full payloads, untimed),
    # and warm the batched gather's jit cache.
    seq_vec = {j: seq_rep.pull(j, since_version=0).version for j in jobs}
    bat_vec = {d.job_id: d.version
               for d in bat_rep.pull_batch([(j, 0) for j in jobs])}
    seq_s = bat_s = 0.0
    for r in range(rounds):
        # A subset of jobs steps between read rounds, so diffs carry
        # real changed rows (round-robin which jobs are dirty).
        dirty = jobs[r % len(jobs):][:3] or jobs[:3]
        for j in dirty:
            eng.step(j, {"target": targets[j]})
        eng.drain()
        rs.refresh()
        t0 = time.perf_counter()
        for j in jobs:
            d = seq_rep.pull(j, since_version=seq_vec[j])
            seq_vec[j] = d.version
        seq_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        diffs = bat_rep.pull_batch([(j, bat_vec[j]) for j in jobs])
        bat_s += time.perf_counter() - t0
        for j, d in zip(jobs, diffs):
            bat_vec[j] = d.version
    speedup = seq_s / max(bat_s, 1e-9)
    return [
        ("read/seq_pull_ms_8jobs", f"{1e3 * seq_s / rounds:.3f}",
         f"{len(jobs)} sequential versioned pulls per round, "
         f"{rounds} rounds, one replica"),
        ("read/batch_pull_ms_8jobs", f"{1e3 * bat_s / rounds:.3f}",
         "same 8 jobs as ONE pull_batch (single jitted gather) per "
         "round"),
        ("read/batch_speedup", f"{speedup:.2f}",
         "sequential / batched wall time at 8 jobs"),
        ("read/batch_2x", str(int(speedup >= BATCH_SPEEDUP_FLOOR)),
         f"acceptance: pull_batch >= {BATCH_SPEEDUP_FLOOR:.0f}x "
         f"sequential per-job pulls at 8 jobs (must be 1)"),
    ]


def _diff_rows():
    import numpy as np

    rounds = 3 if _smoke() else 8
    rt, eng, rs, targets = _build(n_replicas=1)
    jobs = list(targets)
    _run_steps(eng, targets, 2)
    rs.refresh()
    rep = rs.replicas[0]
    eng_vec = {j: eng.pull(j, since_version=0).version for j in jobs}
    rep_vec = {j: rep.pull(j, since_version=0).version for j in jobs}
    eng_bytes = rep_bytes = 0
    mismatches = 0
    for r in range(rounds):
        dirty = jobs[r % len(jobs):][:2] or jobs[:2]
        for j in dirty:
            eng.step(j, {"target": targets[j]})
        eng.drain()
        rs.refresh()  # replica now holds the engine's exact state
        for j in jobs:
            de = eng.pull(j, since_version=eng_vec[j])
            dr = rep.pull(j, since_version=rep_vec[j])
            eng_vec[j], rep_vec[j] = de.version, dr.version
            eng_bytes += de.bytes_wire
            rep_bytes += dr.bytes_wire
            same = (de.full == dr.full
                    and np.array_equal(de.block_ids, dr.block_ids)
                    and np.array_equal(np.asarray(de.data),
                                       np.asarray(dr.data)))
            if not same:
                mismatches += 1
    return [
        ("read/diff_bytes_engine", str(eng_bytes),
         f"{rounds} diff-pull rounds x {len(jobs)} jobs straight off "
         f"the engine"),
        ("read/diff_bytes_replica", str(rep_bytes),
         "identical read schedule served by a replica"),
        ("read/diff_accounting_match",
         str(int(eng_bytes == rep_bytes and mismatches == 0)),
         "acceptance: replica diff pulls ship the same blocks and "
         "charge the same wire bytes as the engine (must be 1)"),
        ("read/publish_snapshot_reuse",
         str(rs.n_reused_snapshot_copies),
         f"publishes that rode the PR-7 rollback copy instead of "
         f"taking their own (of {rs.n_publishes} total)"),
    ]


def rows():
    return (_parity_rows() + _scaling_rows() + _batch_rows()
            + _diff_rows())


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
