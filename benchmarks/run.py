"""Run benchmarks; print name,value,derived CSV (one per paper table).

Options:
  --list          print every benchmark label and exit
  --only SUBSTR   run only modules whose label contains SUBSTR (repeatable)
  --smoke         shrink sweeps for CI (sets HOTPATH_SMOKE=1)
  --json [PATH]   also write the collected rows as JSON
                  (default PATH: BENCH_hotpath.json -- the perf trajectory
                  file seeded by the hotpath benchmark)
"""

import argparse
import importlib
import json
import os
import sys
import time

MODULES = [
    ("fig2", "fig2_utilization"),
    ("fig7", "fig7_single_job"),
    ("fig8+table2", "fig8_packing"),
    ("fig9", "fig9_perf_loss"),
    ("fig10", "fig10_case_study"),
    ("fig11", "fig11_trace_sim"),
    ("table3", "table3_migration"),
    ("migration", "migration_scaling"),
    ("plan", "plan_scaling"),
    ("hotpath", "hotpath_step"),
    ("service_tick", "service_tick"),
    ("elastic_scaling", "elastic_scaling"),
    ("appd", "appd_interference"),
    ("roofline", "roofline"),
    ("recovery", "recovery"),
    ("wire", "wire_path"),
    ("chaos", "chaos_soak"),
    ("read", "read_tier"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print every benchmark label and exit")
    ap.add_argument("--only", action="append", default=None,
                    help="run only modules whose label contains this")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink benchmark sweeps (CI)")
    ap.add_argument("--json", nargs="?", const="BENCH_hotpath.json",
                    default=None, metavar="PATH",
                    help="write rows to PATH as JSON")
    args = ap.parse_args(argv)
    if args.list:
        for label, mod_name in MODULES:
            print(f"{label}\tbenchmarks/{mod_name}.py")
        return
    if args.smoke:
        os.environ["HOTPATH_SMOKE"] = "1"

    labels = [label for label, _ in MODULES]
    if args.only:
        unknown = [pat for pat in args.only
                   if not any(pat in label for label in labels)]
        if unknown:
            raise SystemExit(
                f"error: --only {', '.join(unknown)} matches no benchmark "
                f"label.\nAvailable labels: {', '.join(labels)}")
    selected = [
        (label, name) for label, name in MODULES
        if not args.only or any(pat in label for pat in args.only)
    ]

    print("name,value,derived")
    collected = []
    failures = 0
    for label, mod_name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for name, value, derived in mod.rows():
                print(f'{name},{value},"{derived}"')
                collected.append(
                    {"name": name, "value": value, "derived": derived})
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f'{label}/ERROR,{type(e).__name__},"{e}"', file=sys.stdout)
        print(f'{label}/elapsed_s,{time.time() - t0:.1f},""')

    if args.json:
        payload = {
            "smoke": bool(args.smoke),
            "modules": [label for label, _ in selected],
            "rows": collected,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f'json/written,{len(collected)},"{args.json}"')
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    # `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
    # sys.path; add the root so `benchmarks.<mod>` imports resolve.
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
