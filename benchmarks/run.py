"""Run every benchmark; print name,value,derived CSV (one per paper table)."""

import sys
import time


def main() -> None:
    from benchmarks import (
        appd_interference,
        fig2_utilization,
        fig7_single_job,
        fig8_packing,
        fig9_perf_loss,
        fig10_case_study,
        fig11_trace_sim,
        plan_scaling,
        roofline,
        table3_migration,
    )

    modules = [
        ("fig2", fig2_utilization),
        ("fig7", fig7_single_job),
        ("fig8+table2", fig8_packing),
        ("fig9", fig9_perf_loss),
        ("fig10", fig10_case_study),
        ("fig11", fig11_trace_sim),
        ("table3", table3_migration),
        ("plan", plan_scaling),
        ("appd", appd_interference),
        ("roofline", roofline),
    ]
    print("name,value,derived")
    failures = 0
    for label, mod in modules:
        t0 = time.time()
        try:
            for name, value, derived in mod.rows():
                print(f'{name},{value},"{derived}"')
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f'{label}/ERROR,{type(e).__name__},"{e}"', file=sys.stdout)
        print(f'{label}/elapsed_s,{time.time() - t0:.1f},""')
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
