"""Fig. 9: job performance impact when sharing AutoPS (<= ~9%)."""

from repro.configs.paper_workloads import make_job
from repro.core import ParameterService


def rows():
    out = []
    for model in ("alexnet", "vgg19", "awd-lm", "bert"):
        for n in (2, 4):
            svc = ParameterService(total_budget=64, n_clusters=1)
            for i in range(n):
                svc.register_job(make_job(model, f"{model}-{i}", 2, 2))
            losses = svc.predicted_losses()
            out.append((f"fig9/max_loss/{model}-{n}jobs",
                        f"{max(losses.values()):.4f}",
                        "paper: up to 9% loss; LossLimit=0.1"))
    return out
