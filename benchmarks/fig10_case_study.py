"""Fig. 10 case study: Aggregator scaling timeline around job events.

A VGG19 (2s-2w) job runs steady on 2 Aggregators; an AlexNet (2s-2w) job
arrives (packed, contention), AutoPS's feedback allocates another Aggregator
when the loss bound binds, and the AlexNet exit releases it again."""

from repro.configs.paper_workloads import make_job
from repro.core import ParameterService


def rows():
    # preserve_spread keeps VGG19 on its 2 Aggregators after the co-located
    # job exits, matching the figure (the trace-sim benchmark runs with full
    # consolidation, the default).
    svc = ParameterService(total_budget=16, n_clusters=1, preserve_spread=True)
    timeline = []

    svc.register_job(make_job("vgg19", "vgg", 2, 2))
    timeline.append(("t=0s vgg19 arrives", svc.n_aggregators,
                     max(svc.predicted_losses().values())))

    svc.register_job(make_job("alexnet", "alex", 2, 2))
    timeline.append(("t=11s alexnet packed", svc.n_aggregators,
                     max(svc.predicted_losses().values())))

    svc.job_exit("alex")
    timeline.append(("t=42s alexnet exits", svc.n_aggregators,
                     max(svc.predicted_losses().values())))

    out = []
    for label, aggs, loss in timeline:
        out.append((f"fig10/{label.replace(' ', '_')}", str(aggs),
                    f"max_predicted_loss={loss:.4f}"))
    return out
