"""Benchmark harness: one module per paper table/figure + roofline readout.

Each module exposes rows() -> List[Tuple[name, value, derived]] printed as
CSV by benchmarks.run. Control-plane figures run the real scheduler;
data-plane ones run/measure JAX; the roofline table reads the dry-run JSONs.
"""
