"""Fig. 8 + Table 2: Aggregator counts / CPU reduction under multi-job packing."""

from repro.configs.paper_workloads import make_job
from repro.core import ParameterService

PAPER_TABLE2 = {"alexnet": 0.375, "vgg19": 0.5, "awd-lm": 0.5, "bert": 0.5}


def _run(model, n_jobs, servers, workers):
    svc = ParameterService(total_budget=64, n_clusters=1)
    for i in range(n_jobs):
        svc.register_job(make_job(model, f"{model}-{i}", servers, workers))
    return svc


def rows():
    out = []
    for model in ("alexnet", "vgg19", "awd-lm", "bert"):
        for n in (2, 3, 4):
            svc = _run(model, n, 2, 2)
            out.append((f"fig8/aggregators/{model}-{n}jobs-2s2w",
                        str(svc.n_aggregators),
                        f"baseline={2 * n} reduction={svc.cpu_reduction():.3f}"))
    for model, expected in PAPER_TABLE2.items():
        svc = _run(model, 2, 4, 4)
        out.append((f"table2/reduction/{model}-2jobs-4s4w",
                    f"{svc.cpu_reduction():.3f}", f"paper={expected}"))
    return out
