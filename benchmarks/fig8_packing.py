"""Fig. 8 + Table 2: Aggregator counts / CPU reduction under multi-job packing.

The data-plane columns (shards, padding waste) come from the *compiled*
ServicePlan (`ParameterService.compile_plan()`), i.e. the exact layout the
shared flat aggregation space would use -- not a synthetic re-assignment.
"""

from repro.configs.paper_workloads import make_job
from repro.core import ParameterService
from repro.ps.plan import plan_padding_waste

PAPER_TABLE2 = {"alexnet": 0.375, "vgg19": 0.5, "awd-lm": 0.5, "bert": 0.5}


def _run(model, n_jobs, servers, workers):
    svc = ParameterService(total_budget=64, n_clusters=1)
    for i in range(n_jobs):
        svc.register_job(make_job(model, f"{model}-{i}", servers, workers))
    return svc


def rows():
    out = []
    for model in ("alexnet", "vgg19", "awd-lm", "bert"):
        for n in (2, 3, 4):
            svc = _run(model, n, 2, 2)
            plan = svc.compile_plan()
            out.append((f"fig8/aggregators/{model}-{n}jobs-2s2w",
                        str(svc.n_aggregators),
                        f"baseline={2 * n} reduction={svc.cpu_reduction():.3f}"))
            out.append((f"fig8/plan_waste/{model}-{n}jobs-2s2w",
                        f"{plan_padding_waste(plan):.4f}",
                        f"{len(plan.segments)} segments over "
                        f"{plan.n_shards} shards, "
                        f"{plan.payload_elements * 4 / 1e6:.1f} MB payload"))
    for model, expected in PAPER_TABLE2.items():
        svc = _run(model, 2, 4, 4)
        out.append((f"table2/reduction/{model}-2jobs-4s4w",
                    f"{svc.cpu_reduction():.3f}", f"paper={expected}"))
    return out
