"""Migration scaling: full-gather vs delta vs checkpoint-restart.

The paper's elasticity claim (§5, Table 3) is that aggregations migrate
with *negligible* overhead.  The seed implementation relayouted the
ENTIRE flat space on every replan (one permutation gather over
``old.total_len`` lanes); the delta path (repro.ps.elastic.
compile_migration_delta + repro.kernels.relayout) executes only the
moved runs, so a plan transition costs O(moved bytes), not O(total
state).

This benchmark seeds K co-resident jobs (K = 2/4/8) into one compiled
shared service and times the same two transitions through both
executors (plan-pair structures pre-compiled for both, exactly as a
live service holds them in cache):

  arrival   one small job joins (sorts after every resident job, fits in
            existing shard padding): nothing co-resident moves -- the
            delta is (near-)empty while the full gather still permutes
            every lane of every leaf;
  exit      the first job leaves and survivors consolidate: the delta
            copies only the shifted runs.

The checkpoint-restart strawman (save + cross-plan restore through
repro.checkpoint) is measured once at max K.  Every delta result is
asserted bit-equal to the full-gather oracle before timing is reported.

``run.py --only migration --json BENCH_migration.json`` seeds the
perf-trajectory file; ``--smoke`` (or MIGRATION_SMOKE=1/HOTPATH_SMOKE=1)
shrinks sizes for CI.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_ps_checkpoint, save_ps_checkpoint
from repro.core import ParameterService
from repro.ps.elastic import (
    compile_migration_delta,
    migrate_flat_state,
    migrate_flat_state_delta,
    migration_bytes,
)
from repro.ps.runtime import (
    init_shared_state,
    job_profile_from_tree,
    seed_job_params,
)

JOB_COUNTS = (2, 4, 8)


def _smoke() -> bool:
    return any(os.environ.get(k, "") not in ("", "0")
               for k in ("MIGRATION_SMOKE", "HOTPATH_SMOKE"))


def _tree(seed: int, n_leaves: int, leaf: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    return {f"t{i:03d}": jax.random.normal(k, (leaf,))
            for i, k in enumerate(ks)}


def _build(n_jobs: int, n_leaves: int, leaf: int):
    """K co-resident jobs in ONE service, with a seeded shared state."""
    svc = ParameterService(total_budget=64, n_clusters=1, plan_pad_to=128)
    trees = {f"j{i}": _tree(i, n_leaves, leaf) for i in range(n_jobs)}
    for jid, tree in sorted(trees.items()):
        nbytes = sum(4 * v.size for v in tree.values())
        profile, specs = job_profile_from_tree(
            jid, tree, required_servers=2, agg_throughput=nbytes / 0.4)
        svc.register_job(profile, specs=specs)
    plan = svc.compile_plan()
    state = init_shared_state(plan)
    for jid, tree in trees.items():
        state = seed_job_params(plan, state, jid, tree)
    state["mu"] = jnp.where(state["flat"] != 0, 0.1, 0.0)
    jax.block_until_ready(state["flat"])
    return svc, plan, state


def _copy_state(state):
    return {k: (jax.tree_util.tree_map(lambda x: x.copy(), v)
                if isinstance(v, dict) else v.copy())
            for k, v in state.items()}


def _time_migration(fn, state, repeats: int) -> float:
    """Best wall time of fn(copy_of_state); copies stay outside the timed
    region (the delta path may donate its input buffers)."""
    best = float("inf")
    for _ in range(repeats):
        s = _copy_state(state)
        jax.block_until_ready(s["flat"])
        t0 = time.perf_counter()
        out = fn(s)
        jax.block_until_ready(out["flat"])
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _scenario_rows(name, n_jobs, ctx, old, new, state, repeats, out):
    """Time one (old -> new) transition through both executors."""
    delta = compile_migration_delta(old, new)  # cached, as a live service
    oracle = migrate_flat_state(state, old, new)  # holds it across ticks
    got = migrate_flat_state_delta(_copy_state(state), old, new, delta=delta)
    for k in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(oracle[k]),
                                      np.asarray(got[k]))
    gather_ms = _time_migration(
        lambda s: migrate_flat_state(s, old, new), state, repeats)
    delta_ms = _time_migration(
        lambda s: migrate_flat_state_delta(s, old, new, delta=delta),
        state, repeats)
    mig_bytes = migration_bytes(old, new)
    out.append((f"migration/gather_ms/{name}/jobs{n_jobs}",
                f"{gather_ms:.3f}",
                f"full-space permutation of {old.total_len} lanes x 3 "
                f"leaves; {ctx}"))
    out.append((f"migration/delta_ms/{name}/jobs{n_jobs}",
                f"{delta_ms:.3f}",
                f"{len(delta.moves)} move + {len(delta.zeros)} zero runs, "
                f"{delta.moved_elements} lanes moved; {ctx}"))
    out.append((f"migration/speedup/{name}/jobs{n_jobs}",
                f"{gather_ms / max(delta_ms, 1e-6):.1f}",
                "full-gather ms / delta ms for the same transition"))
    out.append((f"migration/moved_mb/{name}/jobs{n_jobs}",
                f"{delta.moved_bytes() / 1e6:.3f}",
                f"delta-path bytes (master+moments); cross-shard "
                f"migration_bytes={mig_bytes / 1e6:.3f} MB; touched jobs "
                f"{list(delta.touched_jobs)}"))
    return gather_ms, delta_ms, delta, mig_bytes


def rows():
    smoke = _smoke()
    n_leaves = 4 if smoke else 8
    leaf = 512 if smoke else 8192
    repeats = 3 if smoke else 15
    out = []
    accept = {}
    for n_jobs in JOB_COUNTS:
        svc, old, state = _build(n_jobs, n_leaves, leaf)
        ctx = (f"{n_jobs} jobs x {n_leaves} leaves x {leaf} lanes, "
               f"space {old.total_len}")

        # Arrival: a small job (sorted after every resident one) joins.
        probe = _tree(99, max(2, n_leaves // 2), max(128, leaf // 8))
        nb = sum(4 * v.size for v in probe.values())
        profile, specs = job_profile_from_tree(
            "zz-probe", probe, required_servers=1, agg_throughput=nb / 0.4)
        svc.register_job(profile, specs=specs)
        plan_arr = svc.compile_plan()
        _, _, delta, mig_bytes = _scenario_rows(
            "arrival", n_jobs, ctx, old, plan_arr, state, repeats, out)
        if n_jobs == JOB_COUNTS[-1]:
            accept["arrival_delta"] = delta
            accept["arrival_match"] = delta.moved_bytes() == mig_bytes

        # Exit: the first resident job leaves; survivors consolidate.
        state_arr = migrate_flat_state(state, old, plan_arr)
        state_arr = seed_job_params(plan_arr, state_arr, "zz-probe", probe)
        svc.job_exit("j0")
        plan_exit = svc.compile_plan()
        _scenario_rows("exit", n_jobs, ctx, plan_arr, plan_exit, state_arr,
                       repeats, out)

        if n_jobs == JOB_COUNTS[-1]:
            with tempfile.TemporaryDirectory() as d:
                t0 = time.perf_counter()
                save_ps_checkpoint(d, 0, old, state)
                _, restored = restore_ps_checkpoint(d, 0, plan=plan_arr)
                jax.block_until_ready(restored["flat"])
                ckpt_ms = (time.perf_counter() - t0) * 1e3
            out.append((f"migration/ckpt_restart_ms/jobs{n_jobs}",
                        f"{ckpt_ms:.1f}",
                        "checkpoint-restart strawman for the same arrival "
                        "transition (full save + cross-plan restore)"))

    # Acceptance (single-job arrival at max co-residency): the delta path
    # must beat the full gather >= 5x and its moved-bytes accounting must
    # agree with the cross-shard migration_bytes for this transition.
    k1 = JOB_COUNTS[-1]
    g_ms = float(next(v for n, v, _ in out
                      if n == f"migration/gather_ms/arrival/jobs{k1}"))
    d_ms = float(next(v for n, v, _ in out
                      if n == f"migration/delta_ms/arrival/jobs{k1}"))
    ok = g_ms >= 5 * d_ms and bool(accept.get("arrival_match"))
    out.append((
        "migration/delta_5x_and_bytes_match",
        int(ok),
        f"arrival at {k1} jobs: delta {d_ms:.3f} ms vs gather {g_ms:.3f} "
        f"ms ({g_ms / max(d_ms, 1e-6):.1f}x); delta moved bytes "
        f"{accept['arrival_delta'].moved_bytes()} == migration_bytes "
        f"(match={accept.get('arrival_match')})",
    ))
    return out


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        os.environ["MIGRATION_SMOKE"] = "1"
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
